"""Model zoo: unified transformer/MoE/SSM/hybrid assembly."""
from .common import axis_rules, logical_constraint, resolve_specs, LogicalAxes, Initializer, cross_entropy_loss
from .transformer import Model, ModelConfig
from .attention import AttentionConfig
from .mlp import MLPConfig, MoEConfig
from .mamba import MambaConfig
from .rwkv import RWKVConfig

__all__ = [
    "Model", "ModelConfig", "AttentionConfig", "MLPConfig", "MoEConfig",
    "MambaConfig", "RWKVConfig", "axis_rules", "logical_constraint",
    "resolve_specs", "LogicalAxes", "Initializer", "cross_entropy_loss",
]
