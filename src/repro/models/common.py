"""Common model building blocks: param builder with logical sharding axes,
norms, rotary embeddings (incl. M-RoPE), losses.

Sharding follows the MaxText pattern: every parameter and key activation is
tagged with *logical* axis names; a rules table (set per launch context) maps
logical names to mesh axes, and ``with_sharding_constraint`` is a no-op when
no rules are active (CPU tests) or when the dim is not divisible by the mesh
axis (e.g. gemma2's 8 heads on a 16-way model axis stay replicated).
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

PyTree = Any

__all__ = [
    "LogicalAxes", "Initializer", "axis_rules", "logical_constraint",
    "resolve_specs", "rms_norm", "layer_norm", "softcap",
    "rope_frequencies", "apply_rope", "apply_mrope", "make_mrope_positions",
    "cross_entropy_loss", "Param",
]


# --------------------------------------------------------------------------
# logical axis rules
# --------------------------------------------------------------------------
class _Rules(threading.local):
    def __init__(self):
        self.acts: dict[str, Any] = {}
        self.params: dict[str, Any] = {}
        self.mesh = None

    @property
    def rules(self):  # activation rules (logical_constraint path)
        return self.acts


_RULES = _Rules()


@contextlib.contextmanager
def axis_rules(rules: dict[str, Any], mesh=None, param_rules: dict[str, Any] = None):
    """Activate logical->mesh axis rules for the enclosed region.

    ``rules`` applies to activations (``logical_constraint``); ``param_rules``
    (defaults to ``rules``) applies to parameter/state specs
    (``resolve_specs``).  Separating the two enables FSDP-style layouts where
    e.g. 'embed' shards parameters but not activations.  ``mesh`` enables the
    divisibility check (non-divisible dims replicate).
    """
    old = (_RULES.acts, _RULES.params, _RULES.mesh)
    _RULES.acts = dict(rules)
    _RULES.params = dict(param_rules if param_rules is not None else rules)
    _RULES.mesh = mesh
    try:
        yield
    finally:
        _RULES.acts, _RULES.params, _RULES.mesh = old


def _axis_size(mesh_axes) -> int:
    mesh = _RULES.mesh
    if mesh is None:
        return 1
    if isinstance(mesh_axes, str):
        mesh_axes = (mesh_axes,)
    size = 1
    for a in mesh_axes:
        size *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    return size


def _resolve_axes(
    names: Sequence[Optional[str]],
    shape: Optional[Sequence[int]] = None,
    table: Optional[dict] = None,
) -> P:
    """Resolve logical names to mesh axes; each mesh axis is used at most once
    per spec (first divisible dim wins — e.g. qwen2-moe's 60 experts are not
    divisible by the 16-way model axis, so the expert-hidden dim shards
    instead)."""
    table = _RULES.acts if table is None else table
    out = []
    used: set = set()
    for i, name in enumerate(names):
        mesh_axes = table.get(name) if name else None
        if mesh_axes is not None:
            key = tuple(mesh_axes) if isinstance(mesh_axes, (tuple, list)) else (mesh_axes,)
            if any(a in used for a in key):
                mesh_axes = None
            elif shape is not None and shape[i] % max(1, _axis_size(mesh_axes)) != 0:
                mesh_axes = None  # not divisible -> replicate
            else:
                used.update(key)
        out.append(mesh_axes)
    return P(*out)


def logical_constraint(x: jnp.ndarray, *names: Optional[str]) -> jnp.ndarray:
    """Apply a sharding constraint by logical axis names (no-op without rules)."""
    if not _RULES.acts:
        return x
    if len(names) != x.ndim:
        raise ValueError(f"{len(names)} names for rank-{x.ndim} array")
    spec = _resolve_axes(names, x.shape, _RULES.acts)
    if all(s is None for s in spec):
        return x
    mesh = _RULES.mesh
    if mesh is not None:
        from jax.sharding import NamedSharding

        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)


def force_replicated(x: jnp.ndarray) -> jnp.ndarray:
    """Explicitly replicate a tensor across the whole mesh (one up-front
    all-gather instead of partitioner-chosen per-op resharding)."""
    mesh = _RULES.mesh
    if mesh is None or not _RULES.acts:
        return x
    from jax.sharding import NamedSharding

    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*([None] * x.ndim)))
    )


@dataclasses.dataclass(frozen=True)
class LogicalAxes:
    """Pytree *leaf* carrying per-dim logical names for one parameter."""

    names: Tuple[Optional[str], ...]
    shape: Tuple[int, ...] = ()

    def spec(self) -> P:
        return _resolve_axes(self.names, self.shape if self.shape else None, _RULES.params)


def resolve_specs(spec_tree: PyTree, prefix: Tuple = ()) -> PyTree:
    """LogicalAxes tree -> PartitionSpec tree under the active *param* rules.

    ``prefix`` prepends mesh axes (e.g. the decentralized node axis for the
    leading node dim of stacked state arrays)."""
    def one(l: LogicalAxes) -> P:
        spec = l.spec()
        return P(*prefix, *spec) if prefix else spec

    return jax.tree.map(
        lambda l: one(l) if isinstance(l, LogicalAxes) else P(*prefix),
        spec_tree,
        is_leaf=lambda l: isinstance(l, LogicalAxes),
    )


# --------------------------------------------------------------------------
# parameter builder (single source of truth for params AND their specs)
# --------------------------------------------------------------------------
Param = jnp.ndarray


class Initializer:
    """Builds either parameter arrays or their LogicalAxes spec tree from the
    same model-definition code path (mode='params' | 'specs' | 'shapes')."""

    def __init__(self, mode: str, key: Optional[jax.Array] = None, dtype=jnp.float32):
        assert mode in ("params", "specs", "shapes")
        self.mode = mode
        self._key = key
        self.dtype = dtype

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def param(
        self,
        shape: Sequence[int],
        axes: Sequence[Optional[str]],
        init: str = "normal",
        scale: Optional[float] = None,
        dtype=None,
    ):
        shape = tuple(int(s) for s in shape)
        axes = tuple(axes)
        assert len(shape) == len(axes), (shape, axes)
        if self.mode == "specs":
            return LogicalAxes(axes, shape)
        if self.mode == "shapes":
            return jax.ShapeDtypeStruct(shape, dtype or self.dtype)
        dt = dtype or self.dtype
        if init == "zeros":
            return jnp.zeros(shape, dt)
        if init == "ones":
            return jnp.ones(shape, dt)
        if init == "normal":
            fan_in = shape[0] if len(shape) > 1 else max(shape[-1], 1)
            s = scale if scale is not None else 1.0 / math.sqrt(fan_in)
            return (jax.random.normal(self._next_key(), shape) * s).astype(dt)
        if init == "embed":
            s = scale if scale is not None else 1.0
            return (jax.random.normal(self._next_key(), shape) * s).astype(dt)
        raise ValueError(init)


# --------------------------------------------------------------------------
# norms / activations
# --------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6, *, plus_one: bool = False) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if plus_one:  # gemma convention: weight stored as delta from 1
        w = w + 1.0
    return (y * w).astype(dt)


def layer_norm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# --------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def _rotate(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., S, n_heads, head_dim); positions: (..., S) int."""
    freqs = rope_frequencies(x.shape[-1], theta)  # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (.., S, 1, half)
    return _rotate(x, cos, sin)


def make_mrope_positions(batch: int, seq: int, n_vision: int, grid: Tuple[int, int]) -> jnp.ndarray:
    """Qwen2-VL M-RoPE positions (3, B, S): (temporal, height, width).

    Vision tokens occupy the first ``n_vision`` slots with 2-D (h, w) grid
    coordinates and a constant temporal index; text tokens get equal t/h/w
    indices continuing after the vision block (the paper's scheme).
    """
    gh, gw = grid
    assert gh * gw == n_vision, (grid, n_vision)
    hh = jnp.repeat(jnp.arange(gh), gw)
    ww = jnp.tile(jnp.arange(gw), gh)
    tt = jnp.zeros(n_vision, jnp.int32)
    text = jnp.arange(seq - n_vision) + max(gh, gw)
    pos_t = jnp.concatenate([tt, text])
    pos_h = jnp.concatenate([hh, text])
    pos_w = jnp.concatenate([ww, text])
    pos = jnp.stack([pos_t, pos_h, pos_w])  # (3, S)
    return jnp.broadcast_to(pos[:, None, :], (3, batch, seq))


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float, sections: Tuple[int, int, int]) -> jnp.ndarray:
    """Multimodal RoPE: the rotary half-dim is split into (t, h, w) sections,
    each rotated with its own position stream.  x: (B, S, H, hd);
    positions3: (3, B, S)."""
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_frequencies(x.shape[-1], theta)  # (half,)
    # build per-frequency positions by section
    sec_id = jnp.concatenate(
        [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)]
    )  # (half,)
    pos = positions3[sec_id]  # (half, B, S) via take along modality
    pos = jnp.moveaxis(pos, 0, -1)  # (B, S, half)
    ang = pos.astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    return _rotate(x, cos, sin)


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------
def cross_entropy_loss(
    logits: jnp.ndarray, targets: jnp.ndarray, mask: Optional[jnp.ndarray] = None
) -> jnp.ndarray:
    """Token-level cross entropy, fp32 accumulation. logits (..., V), targets (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()
