"""RWKV-6 (Finch) block: attention-free time-mix with data-dependent decay.

Time-mix recurrence per head (state S: head_dim x head_dim):

    w_t = exp(-exp(w0 + lora_w(x~_t)))            # data-dependent decay (Finch)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T S_{t-1} + (r_t . (u . k_t)) v_t   # u = per-channel bonus

plus token-shift lerps on the inputs and a squared-ReLU channel-mix.  The
sequence path scans chunks of the recurrence; decode is the O(1) single-step
recurrence (the ``long_500k`` path — state size is independent of context).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import Initializer, logical_constraint, rms_norm

__all__ = ["RWKVConfig", "init_rwkv", "timemix_forward", "chanmix_forward",
           "init_rwkv_cache", "timemix_decode", "chanmix_decode"]


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    d_model: int
    d_ff: int
    head_dim: int = 64
    decay_lora: int = 64
    chunk: int = 0        # 0 = per-token scan; >0 = chunked linear attention
    chunk_bf16: bool = False  # bf16 chunk operands (f32 accumulation + state)
    use_pallas: bool = False  # chunked wkv via the Pallas kernel (VMEM state)

    @property
    def n_heads(self) -> int:
        assert self.d_model % self.head_dim == 0
        return self.d_model // self.head_dim


def init_rwkv(cfg: RWKVConfig, ini: Initializer):
    d, f = cfg.d_model, cfg.d_ff
    return {
        # time-mix
        "mix_r": ini.param((d,), ("embed",), init="zeros"),
        "mix_k": ini.param((d,), ("embed",), init="zeros"),
        "mix_v": ini.param((d,), ("embed",), init="zeros"),
        "mix_w": ini.param((d,), ("embed",), init="zeros"),
        "mix_g": ini.param((d,), ("embed",), init="zeros"),
        "w_r": ini.param((d, d), ("embed", "heads_flat")),
        "w_k": ini.param((d, d), ("embed", "heads_flat")),
        "w_v": ini.param((d, d), ("embed", "heads_flat")),
        "w_g": ini.param((d, d), ("embed", "heads_flat")),
        "w_o": ini.param((d, d), ("heads_flat", "embed")),
        "decay_base": ini.param((d,), ("heads_flat",), init="zeros"),
        "decay_lora_a": ini.param((d, cfg.decay_lora), ("embed", None)),
        "decay_lora_b": ini.param((cfg.decay_lora, d), (None, "heads_flat"), scale=0.1),
        "bonus_u": ini.param((d,), ("heads_flat",), init="zeros"),
        "ln_x": ini.param((d,), ("heads_flat",), init="ones"),
        # channel-mix
        "cmix_k": ini.param((d,), ("embed",), init="zeros"),
        "cmix_r": ini.param((d,), ("embed",), init="zeros"),
        "cw_k": ini.param((d, f), ("embed", "ffn")),
        "cw_v": ini.param((f, d), ("ffn", "embed")),
        "cw_r": ini.param((d, d), ("embed", "embed")),
    }


def _shift(x, prev=None):
    """Token shift: x_{t-1} with x_{-1} = prev (or zeros)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _lerp(x, x_prev, mu):
    return x + (x_prev - x) * jax.nn.sigmoid(mu.astype(x.dtype))


def _timemix_inputs(cfg, params, x, shifted):
    r_in = _lerp(x, shifted, params["mix_r"])
    k_in = _lerp(x, shifted, params["mix_k"])
    v_in = _lerp(x, shifted, params["mix_v"])
    w_in = _lerp(x, shifted, params["mix_w"])
    g_in = _lerp(x, shifted, params["mix_g"])
    dt = x.dtype
    r = r_in @ params["w_r"].astype(dt)
    k = k_in @ params["w_k"].astype(dt)
    v = v_in @ params["w_v"].astype(dt)
    g = jax.nn.silu(g_in @ params["w_g"].astype(dt))
    lora = jnp.tanh(w_in @ params["decay_lora_a"].astype(dt)) @ params["decay_lora_b"].astype(dt)
    logw = -jnp.exp(
        params["decay_base"].astype(jnp.float32) + lora.astype(jnp.float32)
    )  # log decay < 0
    return r, k, v, g, logw


def _heads(cfg, t):
    b, s, d = t.shape
    return t.reshape(b, s, cfg.n_heads, cfg.head_dim)


_CLAMP = 25.0


def _chunked_wkv(cfg: RWKVConfig, rh, kh, vh, wh, s0):
    """Chunked RWKV-6 recurrence (the memory-roofline fix; see EXPERIMENTS.md
    §Perf).  Instead of streaming the (B,H,P,P) state through HBM per token,
    tokens are processed in chunks of length L: within a chunk the output is
    a masked matmul of decay-weighted r/k (GLA-style kernelization), and the
    state is updated once per chunk — state HBM traffic drops by L and the
    inner products run on the MXU.

    rh/kh/vh: (B,S,H,P); wh: (B,S,H,P) log-decay (<0); s0: (B,H,P,P) fp32.
    Returns (y (B,S,H,P) fp32, s_final).
    """
    b, s, h, p = rh.shape
    lc = min(cfg.chunk, s)
    assert s % lc == 0, (s, lc)
    n = s // lc
    resh = lambda t: t.reshape(b, n, lc, h, p).swapaxes(0, 1)
    rs, ks, vs, ws = resh(rh.astype(jnp.float32)), resh(kh.astype(jnp.float32)), \
        resh(vh.astype(jnp.float32)), resh(wh.astype(jnp.float32))
    mask = jnp.tril(jnp.ones((lc, lc), bool), k=-1)   # strict: y_t reads S_{t-1}

    def body(s_prev, inp):
        r_, k_, v_, w_ = inp                              # (B, L, H, P)
        cum = jnp.cumsum(w_, axis=1)                      # inclusive, <= 0
        cex = cum - w_                                    # exclusive
        total = cum[:, -1]                                # (B, H, P)
        r_t = r_ * jnp.exp(jnp.maximum(cex, -_CLAMP))
        k_t = k_ * jnp.exp(jnp.minimum(-cum, _CLAMP))
        mm = jnp.bfloat16 if cfg.chunk_bf16 else jnp.float32
        f32 = jnp.float32
        scores = jnp.einsum(
            "blhp,bmhp->bhlm", r_t.astype(mm), k_t.astype(mm),
            preferred_element_type=f32,
        )
        scores = jnp.where(mask[None, None], scores, 0.0)
        y = jnp.einsum(
            "bhlm,bmhp->blhp", scores.astype(mm), v_.astype(mm),
            preferred_element_type=f32,
        )
        # incoming-state contribution
        y = y + jnp.einsum(
            "blhp,bhpq->blhq", r_t.astype(mm), s_prev.astype(mm),
            preferred_element_type=f32,
        )
        # state update: S <- diag(exp(total)) S + sum_j k_j exp(total - cum_j) v_j^T
        k_s = k_ * jnp.exp(jnp.maximum(total[:, None] - cum, -_CLAMP))
        s_new = jnp.exp(total)[..., None] * s_prev + jnp.einsum(
            "blhp,blhq->bhpq", k_s.astype(mm), v_.astype(mm),
            preferred_element_type=f32,
        )
        return s_new, y

    s_final, ys = jax.lax.scan(body, s0, (rs, ks, vs, ws))
    y = ys.swapaxes(0, 1).reshape(b, s, h, p)
    return y, s_final


def timemix_forward(cfg: RWKVConfig, params, x, return_cache: bool = False):
    """Full-sequence time-mix. x: (B, S, d) (already layer-normed)."""
    b, s, d = x.shape
    shifted = _shift(x)
    r, k, v, g, logw = _timemix_inputs(cfg, params, x, shifted)
    rh, kh, vh = _heads(cfg, r), _heads(cfg, k), _heads(cfg, v)
    wh = _heads(cfg, logw.astype(jnp.float32))
    u = params["bonus_u"].astype(jnp.float32).reshape(cfg.n_heads, cfg.head_dim)

    if cfg.chunk and s % cfg.chunk == 0:
        s0 = jnp.zeros((b, cfg.n_heads, cfg.head_dim, cfg.head_dim), jnp.float32)
        if cfg.use_pallas:
            from ..kernels import api as kernel_api

            y, s_final = kernel_api.call(
                "wkv_chunk", rh, kh, vh, wh, chunk=cfg.chunk
            )
        else:
            y, s_final = _chunked_wkv(cfg, rh, kh, vh, wh, s0)
        # bonus (current-token) term, diagonal in t
        bonus = jnp.einsum(
            "bshp,bshp->bsh", rh.astype(jnp.float32), u[None, None] * kh.astype(jnp.float32)
        )[..., None] * vh.astype(jnp.float32)
        y = (y + bonus).reshape(b, s, d).astype(x.dtype)
        y = rms_norm(y, params["ln_x"]) * g
        y = logical_constraint(y, "batch", "seq", "embed")
        out = y @ params["w_o"].astype(y.dtype)
        if return_cache:
            return out, {"wkv": s_final, "shift_t": x[:, -1:]}
        return out

    def step(s_prev, inp):
        rt, kt, vt, lw = inp  # (B,H,P) x3, (B,H,P)
        rt32, kt32, vt32 = (t.astype(jnp.float32) for t in (rt, kt, vt))
        y = jnp.einsum("bhp,bhpq->bhq", rt32, s_prev)
        y = y + jnp.einsum("bhp,bhp->bh", rt32, u[None] * kt32)[..., None] * vt32
        s_new = jnp.exp(lw)[..., None] * s_prev + kt32[..., None] * vt32[..., None, :]
        return s_new, y

    s0 = jnp.zeros((b, cfg.n_heads, cfg.head_dim, cfg.head_dim), jnp.float32)
    xs = tuple(t.swapaxes(0, 1) for t in (rh, kh, vh, wh))
    s_final, ys = jax.lax.scan(step, s0, xs)
    y = ys.swapaxes(0, 1).reshape(b, s, d).astype(x.dtype)
    y = rms_norm(y, params["ln_x"]) * g
    y = logical_constraint(y, "batch", "seq", "embed")
    out = y @ params["w_o"].astype(y.dtype)
    if return_cache:
        return out, {"wkv": s_final, "shift_t": x[:, -1:]}
    return out


def chanmix_forward(cfg: RWKVConfig, params, x, return_cache: bool = False):
    """Full-sequence channel-mix (squared ReLU). x: (B, S, d) normed."""
    shifted = _shift(x)
    kc = _lerp(x, shifted, params["cmix_k"]) @ params["cw_k"].astype(x.dtype)
    kc = jnp.square(jax.nn.relu(kc))
    kc = logical_constraint(kc, "batch", "seq", "ffn")
    rc = jax.nn.sigmoid(_lerp(x, shifted, params["cmix_r"]) @ params["cw_r"].astype(x.dtype))
    out = rc * (kc @ params["cw_v"].astype(kc.dtype))
    out = logical_constraint(out, "batch", "seq", "embed")
    if return_cache:
        return out, {"shift_c": x[:, -1:]}
    return out


def init_rwkv_cache(cfg: RWKVConfig, batch: int, dtype=jnp.bfloat16):
    return {
        "wkv": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.head_dim), jnp.float32),
        "shift_t": jnp.zeros((batch, 1, cfg.d_model), dtype),
        "shift_c": jnp.zeros((batch, 1, cfg.d_model), dtype),
    }


def timemix_decode(cfg: RWKVConfig, params, x, cache):
    """One-token time-mix decode. x: (B, 1, d) normed."""
    b = x.shape[0]
    shifted = cache["shift_t"].astype(x.dtype)
    r, k, v, g, logw = _timemix_inputs(cfg, params, x, shifted)
    rh = _heads(cfg, r)[:, 0].astype(jnp.float32)
    kh = _heads(cfg, k)[:, 0].astype(jnp.float32)
    vh = _heads(cfg, v)[:, 0].astype(jnp.float32)
    wh = _heads(cfg, logw.astype(jnp.float32))[:, 0]
    u = params["bonus_u"].astype(jnp.float32).reshape(cfg.n_heads, cfg.head_dim)
    s_prev = cache["wkv"]
    y = jnp.einsum("bhp,bhpq->bhq", rh, s_prev)
    y = y + jnp.einsum("bhp,bhp->bh", rh, u[None] * kh)[..., None] * vh
    s_new = jnp.exp(wh)[..., None] * s_prev + kh[..., None] * vh[..., None, :]
    y = y.reshape(b, 1, cfg.d_model).astype(x.dtype)
    y = rms_norm(y, params["ln_x"]) * g
    out = y @ params["w_o"].astype(y.dtype)
    return out, {"wkv": s_new, "shift_t": x.astype(cache["shift_t"].dtype)}


def chanmix_decode(cfg: RWKVConfig, params, x, cache):
    shifted = cache["shift_c"].astype(x.dtype)
    kc = _lerp(x, shifted, params["cmix_k"]) @ params["cw_k"].astype(x.dtype)
    kc = jnp.square(jax.nn.relu(kc))
    rc = jax.nn.sigmoid(_lerp(x, shifted, params["cmix_r"]) @ params["cw_r"].astype(x.dtype))
    out = rc * (kc @ params["cw_v"].astype(kc.dtype))
    return out, {"shift_c": x.astype(cache["shift_c"].dtype)}
