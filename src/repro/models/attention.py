"""GQA attention: training/prefill path, decode path with KV cache.

Supports: grouped-query attention, causal or bidirectional masks, sliding
windows (gemma2 local layers; windowed ring-buffer cache at decode),
attention-score soft-capping, standard RoPE and M-RoPE.

``impl='xla'`` is the jnp reference; ``impl='pallas'`` dispatches the
flash-attention Pallas kernel (training/prefill only).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import Initializer, apply_mrope, apply_rope, logical_constraint, softcap

__all__ = ["AttentionConfig", "init_attention", "attention_forward", "init_kv_cache", "attention_decode"]

NEG_INF = -2.0e38


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    causal: bool = True
    sliding_window: Optional[int] = None       # None = full attention
    attn_softcap: Optional[float] = None
    rope_theta: float = 10000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # M-RoPE if set
    use_bias: bool = False
    qk_norm: bool = False
    attn_impl: str = "xla"                      # 'xla' | 'pallas'

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads


def init_attention(cfg: AttentionConfig, ini: Initializer):
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": ini.param((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ini.param((d, k, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ini.param((d, k, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ini.param((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.use_bias:
        p["bq"] = ini.param((h, hd), ("heads", "head_dim"), init="zeros")
        p["bk"] = ini.param((k, hd), ("kv_heads", "head_dim"), init="zeros")
        p["bv"] = ini.param((k, hd), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        p["q_norm"] = ini.param((hd,), ("head_dim",), init="ones")
        p["k_norm"] = ini.param((hd,), ("head_dim",), init="ones")
    return p


def _project_qkv(cfg: AttentionConfig, params, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.use_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if cfg.qk_norm:
        from .common import rms_norm

        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if cfg.mrope_sections is not None:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(cfg: AttentionConfig, q, k, v, q_pos, kv_pos, kv_mask=None):
    """Reference scaled-dot-product attention with GQA + window + softcap.

    q: (B, Sq, H, hd); k/v: (B, Skv, K, hd); *_pos: (B, Sq)/(B, Skv).
    """
    b, sq, h, hd = q.shape
    kgroups = cfg.n_kv_heads
    qpk = h // kgroups
    qg = q.reshape(b, sq, kgroups, qpk, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    if cfg.attn_softcap is not None:
        scores = cfg.attn_softcap * jnp.tanh(scores / cfg.attn_softcap)
    mask = jnp.ones((b, sq, k.shape[1]), bool)
    delta = q_pos[:, :, None] - kv_pos[:, None, :]
    if cfg.causal:
        mask &= delta >= 0
    if cfg.sliding_window is not None:
        mask &= jnp.abs(delta) < cfg.sliding_window
    if kv_mask is not None:
        mask &= kv_mask[:, None, :]
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def _blockwise_sdpa(cfg: AttentionConfig, q, k, v, q_pos, kv_pos, block: int = 512):
    """Flash-style attention in pure XLA: scan over KV blocks with an online
    softmax so the (Sq, Skv) score matrix is never materialized.

    This is the jit-level twin of the Pallas kernel (same math, XLA fusions
    instead of explicit VMEM tiles) and the memory-roofline fix for training:
    HBM traffic per layer drops from O(S^2) score tensors to O(S * block).
    """
    b, sq, h, hd = q.shape
    kgroups = cfg.n_kv_heads
    qpk = h // kgroups
    skv = k.shape[1]
    block = min(block, skv)
    assert skv % block == 0, (skv, block)
    nblk = skv // block
    qg = q.reshape(b, sq, kgroups, qpk, hd).astype(jnp.float32)
    scale = 1.0 / (hd ** 0.5)

    kb = k.reshape(b, nblk, block, kgroups, hd).swapaxes(0, 1)
    vb = v.reshape(b, nblk, block, kgroups, hd).swapaxes(0, 1)
    pb = kv_pos.reshape(b, nblk, block).swapaxes(0, 1)

    def body(carry, inp):
        acc, m_prev, l_prev = carry
        k_t, v_t, p_t = inp
        s = jnp.einsum("bskgh,btkh->bkgst", qg, k_t.astype(jnp.float32)) * scale
        if cfg.attn_softcap is not None:
            s = cfg.attn_softcap * jnp.tanh(s / cfg.attn_softcap)
        delta = q_pos[:, :, None] - p_t[:, None, :]
        mask = jnp.ones((b, sq, block), bool)
        if cfg.causal:
            mask &= delta >= 0
        if cfg.sliding_window is not None:
            mask &= jnp.abs(delta) < cfg.sliding_window
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        corr = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[..., None])
        l_cur = l_prev * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bkgst,btkh->bkgsh", p, v_t.astype(jnp.float32))
        return (acc, m_cur, l_cur), ()

    acc0 = jnp.zeros((b, kgroups, qpk, sq, hd), jnp.float32)
    m0 = jnp.full((b, kgroups, qpk, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kgroups, qpk, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def attention_forward(
    cfg: AttentionConfig,
    params,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    return_cache: bool = False,
):
    """Full-sequence (training / prefill) attention.  x: (B, S, d)."""
    q, k, v = _project_qkv(cfg, params, x, positions)
    q = logical_constraint(q, "batch", "seq", "heads", None)
    k = logical_constraint(k, "batch", "seq", "kv_heads", None)
    v = logical_constraint(v, "batch", "seq", "kv_heads", None)
    pos1 = positions[0] if cfg.mrope_sections is not None else positions
    if cfg.attn_impl == "pallas" and cfg.causal:
        from ..kernels import api as kernel_api

        out = kernel_api.call(
            "flash_attention", q, k, v,
            causal=True,
            sliding_window=cfg.sliding_window,
            softcap=cfg.attn_softcap,
        )
    elif cfg.attn_impl == "blockwise":
        out = _blockwise_sdpa(cfg, q, k, v, pos1, pos1)
    else:
        out = _sdpa(cfg, q, k, v, pos1, pos1)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(out.dtype))
    y = logical_constraint(y, "batch", "seq", "embed")
    if return_cache:
        return y, {"k": k, "v": v, "pos": pos1}
    return y


# --------------------------------------------------------------------------
# decode path
# --------------------------------------------------------------------------
def init_kv_cache(cfg: AttentionConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Ring-buffer KV cache.  For sliding-window layers the buffer is only
    ``window`` long — the sub-quadratic-memory decode path for gemma2 local
    layers at 500k context."""
    size = max_len if cfg.sliding_window is None else min(max_len, cfg.sliding_window)
    return {
        "k": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.full((batch, size), -1, jnp.int32),  # -1 = empty slot
    }


def attention_decode(
    cfg: AttentionConfig,
    params,
    x: jnp.ndarray,           # (B, 1, d)
    position: jnp.ndarray,    # (B,) current token position
    cache,
):
    """Single-token decode against the ring-buffer cache."""
    if cfg.mrope_sections is not None:
        pos3 = jnp.broadcast_to(position[None, :, None], (3, x.shape[0], 1))
        q, k_new, v_new = _project_qkv(cfg, params, x, pos3)
    else:
        q, k_new, v_new = _project_qkv(cfg, params, x, position[:, None])
    size = cache["k"].shape[1]
    slot = position % size
    bidx = jnp.arange(x.shape[0])
    k = cache["k"].at[bidx, slot].set(k_new[:, 0].astype(cache["k"].dtype))
    v = cache["v"].at[bidx, slot].set(v_new[:, 0].astype(cache["v"].dtype))
    pos = cache["pos"].at[bidx, slot].set(position)
    kv_mask = pos >= 0
    out = _sdpa(cfg, q, k, v, position[:, None], pos, kv_mask=kv_mask)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(out.dtype))
    return y, {"k": k, "v": v, "pos": pos}
