"""Feed-forward blocks: dense (SwiGLU / GELU) and Mixture-of-Experts.

MoE uses Switch-style capacity-based dispatch (TPU-friendly: static shapes,
no sorting), supports shared experts (qwen2-moe) and a parallel dense
residual branch (arctic).  Experts shard over the 'model' mesh axis
(expert parallelism) via the 'experts' logical axis.

Dispatch layouts (see EXPERIMENTS.md §Perf for the measured comparison):
  'auto'           single global queue set; GSPMD places the scatter/gather.
  'gather_tokens'  replicate tokens before dispatch (refuted experiment —
                   kept selectable for reproducibility of the perf log).
  'grouped'        hierarchical dispatch: tokens split into dispatch_groups
                   groups aligned with the data mesh axis; every group builds
                   per-expert queues with a *local* capacity, so the dispatch
                   scatter and combine gather never cross shards — only
                   expert weights move (textbook expert parallelism).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .common import Initializer, force_replicated, logical_constraint

__all__ = ["MLPConfig", "init_mlp", "mlp_forward", "MoEConfig", "init_moe", "moe_forward"]


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    d_model: int
    d_ff: int
    activation: str = "silu"      # 'silu' (SwiGLU), 'gelu' (GeGLU), 'gelu_plain'
    use_bias: bool = False


def _act(name: str, x):
    if name == "silu":
        return jax.nn.silu(x)
    if name in ("gelu", "gelu_plain"):
        return jax.nn.gelu(x, approximate=True)
    if name == "relu2":  # nemotron/minitron squared ReLU
        return jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def init_mlp(cfg: MLPConfig, ini: Initializer):
    d, f = cfg.d_model, cfg.d_ff
    gated = cfg.activation in ("silu", "gelu")
    p = {
        "w_up": ini.param((d, f), ("embed", "ffn")),
        "w_down": ini.param((f, d), ("ffn", "embed")),
    }
    if gated:
        p["w_gate"] = ini.param((d, f), ("embed", "ffn"))
    if cfg.use_bias:
        p["b_up"] = ini.param((f,), ("ffn",), init="zeros")
        p["b_down"] = ini.param((d,), ("embed",), init="zeros")
    return p


def mlp_forward(cfg: MLPConfig, params, x):
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(x.dtype))
    if cfg.use_bias:
        up = up + params["b_up"].astype(x.dtype)
    if "w_gate" in params:
        gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(x.dtype))
        h = _act(cfg.activation, gate) * up
    else:
        h = _act(cfg.activation, up)
    h = logical_constraint(h, "batch", "seq", "ffn")
    y = jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(x.dtype))
    if cfg.use_bias:
        y = y + params["b_down"].astype(x.dtype)
    return logical_constraint(y, "batch", "seq", "embed")


# --------------------------------------------------------------------------
# Mixture of Experts
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                      # per-expert hidden
    n_experts: int
    top_k: int
    n_shared_experts: int = 0      # qwen2-moe: always-on shared experts
    dense_residual: bool = False   # arctic: parallel dense FFN branch
    dense_d_ff: Optional[int] = None
    capacity_factor: float = 1.25
    activation: str = "silu"
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2
    dispatch_layout: str = "auto"  # 'auto' | 'gather_tokens' | 'grouped'
    dispatch_groups: int = 16


def init_moe(cfg: MoEConfig, ini: Initializer):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": ini.param((d, e), ("embed", "experts")),
        "w_gate": ini.param((e, d, f), ("experts", "embed", "ffn")),
        "w_up": ini.param((e, d, f), ("experts", "embed", "ffn")),
        "w_down": ini.param((e, f, d), ("experts", "ffn", "embed")),
    }
    if cfg.n_shared_experts:
        shared = MLPConfig(d, f * cfg.n_shared_experts, cfg.activation)
        p["shared"] = init_mlp(shared, ini)
    if cfg.dense_residual:
        dense = MLPConfig(d, cfg.dense_d_ff or f, cfg.activation)
        p["dense"] = init_mlp(dense, ini)
    return p


def _dispatch_compute_combine(cfg: MoEConfig, params, tokens, capacity: int, constrain=True):
    """Core capacity-based MoE on a 2-D token matrix (T, d).

    Returns (y (T, d), probs (T, E), onehot (T, k, E), z_sq (scalar)) —
    probs/onehot/z_sq feed the aux losses.  Pure function of its inputs so it
    can be vmapped over token groups for the 'grouped' layout.
    """
    n_tok, d = tokens.shape
    logits = jnp.einsum(
        "td,de->te", tokens.astype(jnp.float32), params["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.top_k)       # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k) within its expert queue
    onehot = jax.nn.one_hot(expert_idx, cfg.n_experts, dtype=jnp.int32)  # (T, k, E)
    flat = onehot.reshape(n_tok * cfg.top_k, cfg.n_experts)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat)  # exclusive prefix count
    pos = (pos_in_expert * flat).sum(-1).reshape(n_tok, cfg.top_k)
    keep = pos < capacity

    # scatter-based dispatch: build (E, C, d) expert queues without ever
    # materializing a (T, E, C) one-hot (65k tokens x 128 experts would be
    # tens of GB).  Dropped tokens (pos >= capacity) scatter into a trash row.
    e_flat = expert_idx.reshape(-1)                    # (T*k,)
    pos_flat = jnp.where(keep, pos, capacity).reshape(-1)
    tok_rep = jnp.repeat(jnp.arange(n_tok), cfg.top_k)
    expert_in = jnp.zeros((cfg.n_experts, capacity + 1, d), tokens.dtype)
    expert_in = expert_in.at[e_flat, pos_flat].add(tokens[tok_rep])
    expert_in = expert_in[:, :capacity]
    if constrain:
        expert_in = logical_constraint(expert_in, "experts", "expert_cap", "embed")

    # expert computation (all experts in one einsum; sharded over 'experts')
    gate = jnp.einsum("ecd,edf->ecf", expert_in, params["w_gate"].astype(expert_in.dtype))
    up = jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"].astype(expert_in.dtype))
    h = _act(cfg.activation, gate) * up
    if constrain:
        h = logical_constraint(h, "experts", "expert_cap", "ffn")
    expert_out = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(h.dtype))

    # gather-based combine: token t sums gate_k * expert_out[e_k, pos_k]
    gathered = expert_out[e_flat, jnp.minimum(pos_flat, capacity - 1)]  # (T*k, d)
    gathered = gathered * (keep.reshape(-1, 1) * gate_vals.reshape(-1, 1)).astype(gathered.dtype)
    y = gathered.reshape(n_tok, cfg.top_k, d).sum(axis=1)
    z = jax.scipy.special.logsumexp(logits, axis=-1)
    return y, probs, onehot, jnp.mean(z * z)


def moe_forward(cfg: MoEConfig, params, x, return_aux: bool = False):
    """x: (B, S, d).  Returns (y, aux_losses)."""
    b, s, d = x.shape
    tokens = x.reshape(b * s, d)
    n_tok = b * s
    if cfg.dispatch_layout == "gather_tokens":
        tokens = force_replicated(tokens)

    groups = cfg.dispatch_groups if cfg.dispatch_layout == "grouped" else 1
    if n_tok % max(groups, 1):
        groups = 1
    if groups > 1:
        per = n_tok // groups
        capacity = int(max(cfg.top_k, cfg.capacity_factor * per * cfg.top_k / cfg.n_experts))
        capacity = min(capacity, per)
        toks_g = tokens.reshape(groups, per, d)
        toks_g = logical_constraint(toks_g, "expert_group", None, "embed")
        # the group dim carries the data-axis sharding; inner constraints are
        # DISABLED: under vmap a with_sharding_constraint would pin the group
        # dim to replicated (None dims are authoritative) and undo the outer
        # group sharding — measured in EXPERIMENTS.md §Perf A3.3.
        y, probs, onehot, z_sq = jax.vmap(
            lambda t: _dispatch_compute_combine(cfg, params, t, capacity, constrain=False)
        )(toks_g)
        y = logical_constraint(y, "expert_group", None, "embed")
        y = y.reshape(b, s, d)
        probs = probs.reshape(n_tok, cfg.n_experts)
        onehot = onehot.reshape(n_tok, cfg.top_k, cfg.n_experts)
        z_sq = z_sq.mean()
    else:
        capacity = int(max(cfg.top_k, cfg.capacity_factor * n_tok * cfg.top_k / cfg.n_experts))
        capacity = min(capacity, n_tok)
        y, probs, onehot, z_sq = _dispatch_compute_combine(cfg, params, tokens, capacity)
        y = y.reshape(b, s, d)

    if cfg.n_shared_experts:
        shared_cfg = MLPConfig(cfg.d_model, cfg.d_ff * cfg.n_shared_experts, cfg.activation)
        y = y + mlp_forward(shared_cfg, params["shared"], x)
    if cfg.dense_residual:
        dense_cfg = MLPConfig(cfg.d_model, cfg.dense_d_ff or cfg.d_ff, cfg.activation)
        y = y + mlp_forward(dense_cfg, params["dense"], x)

    y = logical_constraint(y, "batch", "seq", "embed")
    if not return_aux:
        return y, None

    # aux losses: router z-loss + load-balance (Switch) — fp32
    z_loss = cfg.router_z_loss * z_sq
    frac_tokens = jnp.mean(onehot.astype(jnp.float32).sum(1), axis=0)       # (E,)
    frac_probs = jnp.mean(probs, axis=0)
    lb_loss = cfg.load_balance_loss * cfg.n_experts * jnp.sum(frac_tokens * frac_probs)
    return y, z_loss + lb_loss
