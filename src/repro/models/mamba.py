"""Mamba-2 (SSD) block: chunked selective-state-space scan + decode recurrence.

Full-sequence path uses the standard Mamba-2 chunked algorithm (state-space
duality): within a chunk the output is a masked decay-weighted attention-like
contraction; across chunks a small recurrent state (B, H, P, N) is carried by
``lax.scan``.  Decode advances the same recurrence one token at a time with a
rolling conv window — O(1) per token, the sub-quadratic path used for
``long_500k``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .common import Initializer, logical_constraint, rms_norm

__all__ = ["MambaConfig", "init_mamba", "mamba_forward", "init_mamba_cache", "mamba_decode"]


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_inner: int                  # typically 2 * d_model
    state_dim: int = 64           # N
    head_dim: int = 64            # P
    conv_width: int = 4
    chunk: int = 128

    @property
    def n_heads(self) -> int:
        assert self.d_inner % self.head_dim == 0
        return self.d_inner // self.head_dim


def init_mamba(cfg: MambaConfig, ini: Initializer):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.state_dim, cfg.n_heads
    return {
        # fused input projection -> [z, x, B, C, dt]
        "w_in": ini.param((d, 2 * di + 2 * n + h), ("embed", "ssm_in")),
        "conv_w": ini.param((cfg.conv_width, di + 2 * n), (None, "ssm_in"), scale=0.5),
        "a_log": ini.param((h,), ("heads",), init="zeros"),
        "d_skip": ini.param((h,), ("heads",), init="ones"),
        "dt_bias": ini.param((h,), ("heads",), init="zeros"),
        "norm": ini.param((di,), ("ffn",), init="ones"),
        "w_out": ini.param((di, d), ("ffn", "embed")),
    }


def _project(cfg: MambaConfig, params, u):
    """u: (B, S, d) -> z (B,S,di), xbc (B,S,di+2N), dt (B,S,H) raw."""
    proj = jnp.einsum("bsd,de->bse", u, params["w_in"].astype(u.dtype))
    di, n, h = cfg.d_inner, cfg.state_dim, cfg.n_heads
    z = proj[..., :di]
    xbc = proj[..., di : 2 * di + 2 * n]
    dt_raw = proj[..., 2 * di + 2 * n :]
    return z, xbc, dt_raw


def _conv(cfg: MambaConfig, xbc, conv_w, conv_state=None):
    """Causal depthwise conv over time. xbc: (B, S, C). Returns (y, new_state)."""
    w = conv_w.astype(xbc.dtype)  # (W, C)
    kw = cfg.conv_width
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], kw - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    y = sum(xp[:, i : i + xbc.shape[1]] * w[i] for i in range(kw))
    new_state = xp[:, -(kw - 1):] if kw > 1 else pad
    return jax.nn.silu(y), new_state


def _split_xbc(cfg: MambaConfig, xbc):
    di, n = cfg.d_inner, cfg.state_dim
    x = xbc[..., :di]
    b_in = xbc[..., di : di + n]
    c_in = xbc[..., di + n :]
    return x, b_in, c_in


def _ssd_chunked(cfg: MambaConfig, a, xh, b_in, c_in, dt, h0=None):
    """Chunked SSD scan.

    a: (H,) negative per-head decay rate.
    xh: (B, S, H, P); b_in/c_in: (B, S, N); dt: (B, S, H) post-softplus.
    Returns y (B, S, H, P), final state (B, H, P, N) fp32.
    """
    bsz, s, nh, p = xh.shape
    n = b_in.shape[-1]
    lc = min(cfg.chunk, s)
    assert s % lc == 0, (s, lc)
    nchunk = s // lc
    mask = jnp.tril(jnp.ones((lc, lc), bool))

    def reshape_c(t):
        return t.reshape(bsz, nchunk, lc, *t.shape[2:]).swapaxes(0, 1)

    xs = (reshape_c(xh), reshape_c(b_in), reshape_c(c_in), reshape_c(dt))
    if h0 is None:
        h0 = jnp.zeros((bsz, nh, p, n), jnp.float32)

    def chunk_body(h_prev, inp):
        xk, bk, ck, dtk = inp  # (B,lc,H,P), (B,lc,N), (B,lc,N), (B,lc,H)
        xk32 = xk.astype(jnp.float32)
        dtk32 = dtk.astype(jnp.float32)
        loga = dtk32 * a  # (B, lc, H)
        cum = jnp.cumsum(loga, axis=1)
        total = cum[:, -1]  # (B, H)
        # decay matrix L[t, j] = exp(cum_t - cum_j), j <= t
        diff = cum[:, :, None, :] - cum[:, None, :, :]  # (B, lc, lc, H)
        l_mat = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        cb = jnp.einsum("btn,bjn->btj", ck.astype(jnp.float32), bk.astype(jnp.float32))
        scores = cb[..., None] * l_mat * dtk32[:, None, :, :]        # (B,t,j,H)
        y_intra = jnp.einsum("btjh,bjhp->bthp", scores, xk32)
        y_state = (
            jnp.einsum("btn,bhpn->bthp", ck.astype(jnp.float32), h_prev)
            * jnp.exp(cum)[..., None]
        )
        w_j = jnp.exp(total[:, None, :] - cum) * dtk32               # (B, lc, H)
        dh = jnp.einsum("bjh,bjn,bjhp->bhpn", w_j, bk.astype(jnp.float32), xk32)
        h_new = jnp.exp(total)[..., None, None] * h_prev + dh
        return h_new, (y_intra + y_state).astype(xh.dtype)

    h_final, ys = jax.lax.scan(chunk_body, h0, xs)
    y = ys.swapaxes(0, 1).reshape(bsz, s, nh, p)
    return y, h_final


def mamba_forward(cfg: MambaConfig, params, u, return_cache: bool = False):
    """Full-sequence forward. u: (B, S, d_model)."""
    z, xbc, dt_raw = _project(cfg, params, u)
    xbc, conv_state = _conv(cfg, xbc, params["conv_w"])
    x, b_in, c_in = _split_xbc(cfg, xbc)
    bsz, s, _ = x.shape
    xh = x.reshape(bsz, s, cfg.n_heads, cfg.head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    y, h_final = _ssd_chunked(cfg, a, xh, b_in, c_in, dt)
    y = y + xh.astype(jnp.float32).astype(y.dtype) * params["d_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    y = logical_constraint(y, "batch", "seq", "ffn")
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(y.dtype))
    out = logical_constraint(out, "batch", "seq", "embed")
    if return_cache:
        return out, {"conv": conv_state, "ssm": h_final}
    return out


def init_mamba_cache(cfg: MambaConfig, batch: int, dtype=jnp.bfloat16):
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner + 2 * cfg.state_dim), dtype),
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.state_dim), jnp.float32),
    }


def mamba_decode(cfg: MambaConfig, params, u, cache):
    """One-token decode. u: (B, 1, d_model)."""
    z, xbc, dt_raw = _project(cfg, params, u)
    xbc, conv_state = _conv(cfg, xbc, params["conv_w"], conv_state=cache["conv"])
    x, b_in, c_in = _split_xbc(cfg, xbc)
    bsz = x.shape[0]
    xh = x.reshape(bsz, cfg.n_heads, cfg.head_dim).astype(jnp.float32)
    dt = jax.nn.softplus(
        dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # (B, H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a)  # (B, H)
    h = cache["ssm"]
    dh = jnp.einsum("bh,bn,bhp->bhpn", dt, b_in[:, 0].astype(jnp.float32), xh)
    h_new = decay[..., None, None] * h + dh
    y = jnp.einsum("bn,bhpn->bhp", c_in[:, 0].astype(jnp.float32), h_new)
    y = y + xh * params["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(bsz, 1, cfg.d_inner).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(y.dtype))
    return out, {"conv": conv_state.astype(cache["conv"].dtype), "ssm": h_new}
