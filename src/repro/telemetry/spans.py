"""Phase spans: fenced host-side timers + device-trace annotations.

JAX dispatch is asynchronous — an unfenced ``time.time()`` around a jitted
call measures dispatch, not work.  A :func:`span` is the ONE honest timer:
it opens a ``jax.profiler.TraceAnnotation`` (so the phase shows up in a
profiler trace captured with :func:`profile_trace`), hands the caller a
handle whose ``fence(tree)`` calls ``jax.block_until_ready`` on the phase's
outputs, and records the fenced duration into the hub's ``span_seconds``
histogram (labeled by phase) plus a first-class JSONL ``span`` event.

Usage::

    with span(hub, "gossip", step=r) as sp:
        state, key = comm_phase(state, key)
        sp.fence(state)

With ``hub`` ``None`` (or spans disabled on the hub) the context manager is
a complete no-op — no annotation, no fence, no timing — so un-instrumented
code paths stay exactly as fast and exactly as traced as before.

For annotations INSIDE jitted code (where host timers cannot reach) the
engines use ``jax.named_scope`` directly at the trace sites (round executor
phases, ``ChannelSession.mix`` sends, the bucketed kernel launcher); those
only attach metadata to the emitted HLO and never change numerics.
"""
from __future__ import annotations

import contextlib
import time
from typing import Iterator, Optional

import jax

__all__ = ["span", "profile_trace", "fence"]


def fence(tree) -> None:
    """Block until every array in ``tree`` is ready (non-arrays ignored)."""
    jax.block_until_ready(tree)


class _SpanHandle:
    """Handle yielded by :func:`span`; ``fence`` outputs before span close."""

    __slots__ = ("active",)

    def __init__(self, active: bool):
        self.active = active

    def fence(self, tree) -> None:
        if self.active:
            jax.block_until_ready(tree)


_NULL_HANDLE = _SpanHandle(active=False)


@contextlib.contextmanager
def span(hub, phase: str, *, step: Optional[int] = None) -> Iterator[_SpanHandle]:
    """Time one phase, fenced; no-op when ``hub`` is None or spans are off."""
    if hub is None or not getattr(hub, "spans", False):
        yield _NULL_HANDLE
        return
    with jax.profiler.TraceAnnotation(f"repro/{phase}"):
        t0 = time.perf_counter()
        yield _SpanHandle(active=True)
        dt = time.perf_counter() - t0
    hub.record("span_seconds", dt, step=step, label=phase)
    hub.record_event(
        {"event": "span", "phase": phase, "step": step, "seconds": dt}
    )


@contextlib.contextmanager
def profile_trace(trace_dir: Optional[str]) -> Iterator[None]:
    """Bracket a block in ``jax.profiler.start_trace``/``stop_trace`` when
    ``trace_dir`` is set; plain passthrough when it is None/empty.  Backs the
    ``--profile DIR`` flags on the train CLI, sweep and benchmark harness."""
    if not trace_dir:
        yield
        return
    jax.profiler.start_trace(trace_dir)
    try:
        yield
    finally:
        jax.profiler.stop_trace()
