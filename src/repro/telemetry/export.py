"""Run-stamped exporters: JSONL event sink + Prometheus text exposition.

Every exported record carries the hub's run metadata (git SHA, jax version,
device kind, config hash) so any line of any artifact can be traced back to
the exact code + config + hardware that produced it — the property the
serving plane's SLO reports and the sweep grids were missing.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import subprocess
from typing import Any, Dict, Optional

__all__ = [
    "run_metadata", "config_hash", "write_jsonl", "prometheus_text",
    "RecordCursor", "JsonlWriter",
]

_GIT_SHA: Optional[str] = None


def _git_sha() -> str:
    """Memoized: one subprocess per process, not one per hub — benchmarks
    build many hubs and the runtime stamps every worker's records
    (``benchmarks/common.run_stamp`` is the same cached value)."""
    global _GIT_SHA
    if _GIT_SHA is None:
        try:
            out = subprocess.run(
                ["git", "rev-parse", "HEAD"],
                cwd=os.path.dirname(os.path.abspath(__file__)),
                capture_output=True, text=True, timeout=5,
            )
            sha = out.stdout.strip()
            _GIT_SHA = sha if out.returncode == 0 and sha else "unknown"
        except (OSError, subprocess.SubprocessError):
            _GIT_SHA = "unknown"
    return _GIT_SHA


def config_hash(config: Any) -> str:
    """Stable short hash of any JSON-able config (non-JSON-able values fall
    back to ``repr`` so dataclasses/argparse namespaces hash too)."""
    blob = json.dumps(config, sort_keys=True, default=repr)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def run_metadata(config: Any = None, process: Optional[str] = None) -> Dict[str, str]:
    """The stamp on every exported record: where (device + pid), what (git
    SHA, jax version) and with which knobs (config hash) this run happened.
    ``process`` names the role in a multi-process run (``"coordinator"``,
    ``"worker:3"``) so records merged into one stream stay attributable."""
    import jax

    dev = jax.devices()[0]
    meta = {
        "git_sha": _git_sha(),
        "jax_version": jax.__version__,
        "device_kind": f"{dev.platform}:{getattr(dev, 'device_kind', '?')}",
        "config_hash": config_hash(config),
        "pid": str(os.getpid()),
    }
    if process is not None:
        meta["process"] = str(process)
    return meta


def write_jsonl(hub, path: str) -> int:
    """Dump a hub to a JSONL event stream and return the record count.

    Line 1 is a ``meta`` record; then every raw event (phase spans, in
    emission order) and every stream sample, each stamped with the run
    metadata under ``"run"``.

    This is :class:`RecordCursor` + :class:`JsonlWriter` — the exact
    stamping path the elastic runtime drains workers through — run once
    over a whole hub, so locally-exported and runtime-drained records can
    never skew in shape.
    """
    writer = JsonlWriter(path, hub.meta, streams=list(hub.streams))
    try:
        writer.append(RecordCursor(hub).drain(totals=True))
    finally:
        writer.close()
    return writer.count


class RecordCursor:
    """Incremental drain of a hub: each :meth:`drain` returns the records —
    events and stream samples, in the same shapes :func:`write_jsonl` emits,
    each stamped with the hub's run metadata — that arrived since the last
    drain.  The elastic runtime's workers drain once per round and ship the
    chunk over the control channel; the coordinator's :class:`JsonlWriter`
    appends the chunks to ONE merged stream file."""

    def __init__(self, hub):
        self.hub = hub
        self._event_pos = 0
        self._series_pos: Dict[Any, int] = {}

    def drain(self, *, totals: bool = False) -> list:
        """``totals=True`` additionally emits each counter's running total
        after its samples — only meaningful for a one-shot full dump (a
        periodic drainer would re-emit the totals every period; the runtime
        drains with the default and reads totals off ``/metrics`` instead).
        """
        out = []

        def stamp(rec: Dict[str, Any]) -> Dict[str, Any]:
            rec["run"] = self.hub.meta
            return rec

        events = self.hub.events
        for ev in events[self._event_pos:]:
            out.append(stamp(dict(ev)))
        self._event_pos = len(events)
        for name in self.hub.streams:
            spec = self.hub.spec(name)
            for label in self.hub.labels(name):
                steps, vals = self.hub.series(name, label)
                start = self._series_pos.get((name, label), 0)
                for step, value in zip(steps[start:], vals[start:]):
                    v = value.tolist() if hasattr(value, "tolist") else value
                    out.append(stamp({
                        "event": "sample", "stream": name,
                        "kind": spec.kind, "axis": spec.axis,
                        "label": label, "step": int(step), "value": v,
                    }))
                self._series_pos[(name, label)] = len(steps)
                if totals and spec.kind == "counter":
                    out.append(stamp({
                        "event": "total", "stream": name, "label": label,
                        "total": self.hub.total(name, label),
                    }))
        return out


class JsonlWriter:
    """Append-only JSONL sink for PRE-STAMPED records (each record carries
    its origin's ``"run"`` metadata — the coordinator merges many processes'
    cursors into one file).  Line 1 is a ``meta`` record stamped with the
    OWNING hub's metadata, mirroring :func:`write_jsonl`'s layout."""

    def __init__(self, path: str, meta: Dict[str, Any],
                 streams: Optional[list] = None):
        dirname = os.path.dirname(os.path.abspath(path))
        os.makedirs(dirname, exist_ok=True)
        self.path = path
        self.count = 0
        self._f = open(path, "w")
        head: Dict[str, Any] = {"event": "meta"}
        if streams is not None:
            head["streams"] = list(streams)
        head["run"] = dict(meta)
        self.append([head])

    def append(self, records) -> int:
        for rec in records:
            self._f.write(json.dumps(rec) + "\n")
            self.count += 1
        self._f.flush()
        return self.count

    def close(self) -> None:
        self._f.close()


def _prom_name(name: str) -> str:
    return re.sub(r"[^a-zA-Z0-9_]", "_", name)


def prometheus_text(hub, prefix: str = "repro") -> str:
    """Render the hub as Prometheus text exposition format v0.0.4.

    gauges -> latest sample; counters -> ``_total``; histograms ->
    ``_count``/``_sum``.  Per-node/replica vector samples are expanded into
    an ``index`` label so per-replica staleness/age gauges stay addressable.
    """
    import numpy as np

    lines = []
    run_labels = ",".join(
        f'{_prom_name(k)}="{v}"' for k, v in sorted(hub.meta.items())
    )
    lines.append(f"# HELP {prefix}_run_info run metadata stamp")
    lines.append(f"# TYPE {prefix}_run_info gauge")
    lines.append(f"{prefix}_run_info{{{run_labels}}} 1")

    def fmt(metric: str, value: float, label: str = "", index=None) -> str:
        parts = []
        if label:
            parts.append(f'label="{label}"')
        if index is not None:
            parts.append(f'index="{index}"')
        body = "{" + ",".join(parts) + "}" if parts else ""
        return f"{metric}{body} {float(value):g}"

    for name, entry in hub.collect().items():
        spec = entry["spec"]
        kind = spec["kind"]
        series_map = entry["series"]
        if not series_map:
            if kind == "gauge":
                continue  # a never-sampled gauge has no meaningful value
            # counters/histograms are well-defined at zero records: scrapes
            # must see `_total 0` / `_count 0` so rate() starts from zero
            series_map = {"": {"total": 0.0,
                               "summary": {"count": 0, "sum": 0.0}}}
        metric = f"{prefix}_{_prom_name(name)}"
        prom_type = {"gauge": "gauge", "counter": "counter",
                     "histogram": "summary"}[kind]
        suffix = "_total" if kind == "counter" else ""
        if spec["doc"]:
            lines.append(f"# HELP {metric}{suffix} {spec['doc']}")
        lines.append(f"# TYPE {metric}{suffix} {prom_type}")
        for label, series in series_map.items():
            if kind == "counter":
                lines.append(fmt(metric + "_total", series["total"], label))
            elif kind == "histogram":
                summ = series.get("summary", {"count": 0})
                lines.append(fmt(metric + "_count", summ.get("count", 0), label))
                lines.append(fmt(metric + "_sum", summ.get("sum", 0.0), label))
            else:
                last = series["values"][-1] if series["values"] else None
                if last is None:
                    continue
                arr = np.asarray(last)
                if arr.ndim == 0:
                    lines.append(fmt(metric, float(arr), label))
                else:
                    for i, v in enumerate(arr.ravel()):
                        lines.append(fmt(metric, float(v), label, index=i))
    return "\n".join(lines) + "\n"
