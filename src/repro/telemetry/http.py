"""The live fleet-health plane: a minimal stdlib HTTP server.

The elastic runtime's coordinator already knows everything an operator asks
a fleet: the Prometheus exposition of every stream ever recorded anywhere
in the run (workers drain into the coordinator hub), the membership state
(epoch, dead/suspended workers, heartbeat ages) and the stitched recent
trace.  :class:`FleetServer` exposes exactly that over HTTP, pull-style —
the shape Prometheus/infra tooling expects — with zero new dependencies:

  ``/metrics``      text/plain Prometheus exposition (the hub's
                    ``prometheus_text``);
  ``/healthz``      JSON membership snapshot — epoch, live/dead/suspended
                    workers, heartbeat ages, current round; HTTP 200 while
                    the fleet is whole, 503 when any worker is dead or
                    suspended (so a load-balancer health check DTRT);
  ``/trace``        JSON ``{"traceEvents": [...]}`` of the recent stitched
                    spans (loadable in Perfetto as-is);
  ``/diagnostics``  JSON ``DiagnosticsMonitor.diagnose()`` report.

Routes are plain zero-argument callables returning fresh snapshots; the
server runs them on its own daemon threads, so producers hand in callbacks
that take whatever lock guards their state.  Unset routes 404; a callback
raising yields 500 with the error text rather than killing the server.
"""
from __future__ import annotations

import http.server
import json
import threading
from typing import Any, Callable, Dict, Optional, Tuple

__all__ = ["FleetServer"]

Route = Callable[[], Any]


class _Handler(http.server.BaseHTTPRequestHandler):
    server_version = "repro-fleet/1.0"
    protocol_version = "HTTP/1.1"

    def log_message(self, fmt, *args):  # keep stdout clean for the CLIs
        pass

    def do_GET(self):  # noqa: N802 (stdlib handler contract)
        path = self.path.split("?", 1)[0]
        if path != "/" and path.endswith("/"):
            path = path.rstrip("/")
        fn = self.server.routes.get(path)  # type: ignore[attr-defined]
        if fn is None:
            self._reply(404, "text/plain",
                        "not found; routes: "
                        + ", ".join(sorted(self.server.routes)))  # type: ignore[attr-defined]
            return
        try:
            status, ctype, body = fn()
        except Exception as exc:  # a broken probe must not kill the server
            self._reply(500, "text/plain", f"probe error: {exc!r}")
            return
        self._reply(status, ctype, body)

    def _reply(self, status: int, ctype: str, body) -> None:
        data = body if isinstance(body, bytes) else str(body).encode()
        self.send_response(status)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        try:
            self.wfile.write(data)
        except (BrokenPipeError, ConnectionResetError):
            pass


class _Server(http.server.ThreadingHTTPServer):
    daemon_threads = True
    allow_reuse_address = True
    routes: Dict[str, Callable[[], Tuple[int, str, Any]]]


class FleetServer:
    """Serve fleet health over HTTP from producer callbacks.

    All callbacks are optional; omitted ones 404.  ``port=0`` binds an
    ephemeral port (read :attr:`port` / :attr:`url` after :meth:`start`).

    metrics:      () -> Prometheus exposition text.
    health:       () -> JSON-able dict; key ``"ok"`` (default True) decides
                  between HTTP 200 and 503.
    trace:        () -> list of Chrome trace events (recent stitched spans).
    diagnostics:  () -> JSON-able diagnose() report.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0, *,
                 metrics: Optional[Route] = None,
                 health: Optional[Route] = None,
                 trace: Optional[Route] = None,
                 diagnostics: Optional[Route] = None):
        self._host = host
        self._want_port = int(port)
        self._server: Optional[_Server] = None
        self._thread: Optional[threading.Thread] = None
        self._routes: Dict[str, Callable[[], Tuple[int, str, Any]]] = {}
        if metrics is not None:
            self._routes["/metrics"] = lambda: (
                200, "text/plain; version=0.0.4", metrics())
        if health is not None:
            def _health():
                snap = dict(health())
                ok = bool(snap.get("ok", True))
                return (200 if ok else 503, "application/json",
                        json.dumps(snap))
            self._routes["/healthz"] = _health
        if trace is not None:
            self._routes["/trace"] = lambda: (
                200, "application/json",
                json.dumps({"traceEvents": list(trace()),
                            "displayTimeUnit": "ms"}))
        if diagnostics is not None:
            self._routes["/diagnostics"] = lambda: (
                200, "application/json", json.dumps(diagnostics()))

    def start(self) -> "FleetServer":
        server = _Server((self._host, self._want_port), _Handler)
        server.routes = self._routes
        self._server = server
        self._thread = threading.Thread(
            target=server.serve_forever, kwargs={"poll_interval": 0.1},
            name="fleet-http", daemon=True)
        self._thread.start()
        return self

    @property
    def port(self) -> int:
        if self._server is None:
            raise RuntimeError("FleetServer not started")
        return self._server.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self._host}:{self.port}"

    def close(self) -> None:
        if self._server is not None:
            self._server.shutdown()
            self._server.server_close()
            self._server = None
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
