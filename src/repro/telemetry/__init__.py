"""Unified telemetry: one registry, phase spans, run-stamped exporters.

    from repro.telemetry import Telemetry, span, profile_trace

    hub = Telemetry(config={"algorithm": "dse_mvr", "tau": 4})
    sim = Simulator(alg, topo, loss, data, batch_size=8, telemetry=hub)
    state, key = sim.run(state, key, n_rounds=32)
    hub.export_jsonl("run.jsonl")          # spans + streams + link bytes
    print(hub.prometheus())                # text exposition

See ``registry.py`` (the hub + typed stream registry), ``spans.py``
(fenced phase timers, ``--profile`` trace bracketing) and ``export.py``
(JSONL sink, Prometheus text, run metadata).
"""
from .registry import (
    RUNTIME_STREAM_FIELDS,
    SERVING_STREAM_FIELDS,
    STREAM_AXES,
    STREAM_KINDS,
    TRAINING_STREAM_FIELDS,
    StreamSpec,
    Telemetry,
    register_runtime_streams,
    register_training_streams,
)
from .export import (
    JsonlWriter,
    RecordCursor,
    config_hash,
    prometheus_text,
    run_metadata,
    write_jsonl,
)
from .spans import fence, profile_trace, span

__all__ = [
    "Telemetry",
    "StreamSpec",
    "STREAM_KINDS",
    "STREAM_AXES",
    "TRAINING_STREAM_FIELDS",
    "SERVING_STREAM_FIELDS",
    "RUNTIME_STREAM_FIELDS",
    "register_training_streams",
    "register_runtime_streams",
    "run_metadata",
    "config_hash",
    "write_jsonl",
    "prometheus_text",
    "RecordCursor",
    "JsonlWriter",
    "span",
    "profile_trace",
    "fence",
]
