"""Unified telemetry: one registry, phase spans, run-stamped exporters.

    from repro.telemetry import Telemetry, span, profile_trace

    hub = Telemetry(config={"algorithm": "dse_mvr", "tau": 4})
    sim = Simulator(alg, topo, loss, data, batch_size=8, telemetry=hub)
    state, key = sim.run(state, key, n_rounds=32)
    hub.export_jsonl("run.jsonl")          # spans + streams + link bytes
    print(hub.prometheus())                # text exposition

See ``registry.py`` (the hub + typed stream registry), ``spans.py``
(fenced phase timers, ``--profile`` trace bracketing), ``export.py``
(JSONL sink, Prometheus text, run metadata), ``trace.py`` (cross-process
causal tracing -> Chrome trace-event / Perfetto JSON), ``diagnostics.py``
(online convergence diagnostics + anomaly events) and ``http.py`` (the
coordinator's live /metrics /healthz /trace fleet-health plane).
"""
from .registry import (
    RUNTIME_STREAM_FIELDS,
    SERVING_STREAM_FIELDS,
    STREAM_AXES,
    STREAM_KINDS,
    TRAINING_STREAM_FIELDS,
    StreamSpec,
    Telemetry,
    register_runtime_streams,
    register_training_streams,
)
from .export import (
    JsonlWriter,
    RecordCursor,
    config_hash,
    prometheus_text,
    run_metadata,
    write_jsonl,
)
from .spans import fence, profile_trace, span
from .trace import (
    TraceRecorder,
    new_run_id,
    round_trace_id,
    trace_events,
    trace_index,
    write_chrome_trace,
)
from .diagnostics import DiagnosticsMonitor, OnlineStat
from .http import FleetServer

__all__ = [
    "Telemetry",
    "StreamSpec",
    "STREAM_KINDS",
    "STREAM_AXES",
    "TRAINING_STREAM_FIELDS",
    "SERVING_STREAM_FIELDS",
    "RUNTIME_STREAM_FIELDS",
    "register_training_streams",
    "register_runtime_streams",
    "run_metadata",
    "config_hash",
    "write_jsonl",
    "prometheus_text",
    "RecordCursor",
    "JsonlWriter",
    "span",
    "profile_trace",
    "fence",
    "TraceRecorder",
    "new_run_id",
    "round_trace_id",
    "trace_events",
    "trace_index",
    "write_chrome_trace",
    "DiagnosticsMonitor",
    "OnlineStat",
    "FleetServer",
]
