"""The ONE metrics registry: typed streams for training, sweeps and serving.

Before this package, observability was split across three surfaces that could
not be correlated: the scenario engine's on-device stream dicts
(``repro.scenarios.metrics``), the serving plane's host-side recorder
(``repro.serving.metrics.ServingMetrics``), and the kernel backend's
trace-time launch counters (``repro.kernels.api``).  The :class:`Telemetry`
hub absorbs all three behind one ``register_stream`` / ``record`` /
``collect`` API:

  * a **stream** is a named, typed series — ``gauge`` (sampled value),
    ``counter`` (monotone accumulation; ``record`` takes increments) or
    ``histogram`` (observations summarized at collect time) — declared over
    an axis (``scalar``, ``node``, ``replica``) and optionally split by a
    string ``label`` (per-buffer link bytes, per-op kernel launches,
    per-phase span durations);
  * every hub carries immutable **run metadata** (git SHA, jax version,
    device kind, config hash — see :func:`repro.telemetry.export.
    run_metadata`) stamped onto every exported record;
  * exporters live in ``repro.telemetry.export``: a run-stamped JSONL event
    sink (:meth:`Telemetry.export_jsonl`) and a Prometheus-style text
    exposition (:meth:`Telemetry.prometheus`).

The hub is deliberately host-side and append-only: jitted code stays pure
(the engines' scan emits stream arrays; the hub consumes them afterwards),
so attaching telemetry never changes a traced computation — disabled
telemetry is the exact current behavior by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "STREAM_KINDS",
    "STREAM_AXES",
    "StreamSpec",
    "Telemetry",
    "TRAINING_STREAM_FIELDS",
    "SERVING_STREAM_FIELDS",
    "RUNTIME_STREAM_FIELDS",
]

STREAM_KINDS = ("gauge", "counter", "histogram")
STREAM_AXES = ("scalar", "node", "replica")

#: the scenario engine's per-round on-device streams (the functions computing
#: them stay in ``repro.scenarios.metrics`` — pure jnp, scanned on device —
#: but their REGISTRY entries live here, the one place stream names are
#: declared; ``scenarios.metrics.STREAM_FIELDS`` re-exports this tuple).
TRAINING_STREAM_FIELDS = (
    "consensus", "tracking_err", "spectral_gap", "active_nodes",
    "compression_err", "replica_drift", "staleness", "send_rate",
)

#: the serving plane's per-publish / per-load-run streams (recorded by
#: ``repro.serving.metrics.ServingMetrics``, which is backed by a hub).
SERVING_STREAM_FIELDS = (
    "staleness", "snapshot_age", "send_rate", "published_kbytes",
    "requests_per_sec",
)

#: the elastic runtime's membership / liveness / resync streams
#: (``repro.runtime``): coordinator-side membership and round timing, plus
#: the per-worker contribution times streamed over the control channel.
RUNTIME_STREAM_FIELDS = (
    "membership_epoch", "active_workers", "heartbeat_age",
    "round_seconds", "contrib_seconds", "resync_seconds",
)


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """Declarative stream registration.

    kind: "gauge" — each record is a sampled value; "counter" — each record
          is an INCREMENT, the hub tracks the monotone total; "histogram" —
          each record is one observation, summarized (count/mean/percentiles)
          at collect time.
    axis: the shape of one sample — "scalar" (a float) or a per-"node" /
          per-"replica" vector (stored as-is; exporters reduce or expand
          per label as appropriate).
    """

    name: str
    kind: str = "gauge"
    axis: str = "scalar"
    unit: str = ""
    doc: str = ""

    def __post_init__(self):
        if self.kind not in STREAM_KINDS:
            raise ValueError(f"stream kind {self.kind!r} not in {STREAM_KINDS}")
        if self.axis not in STREAM_AXES:
            raise ValueError(f"stream axis {self.axis!r} not in {STREAM_AXES}")


# the hub's built-in cross-cutting streams, registered on every hub so the
# span/link/kernel plumbing can record without per-call-site registration
_BUILTIN_STREAMS = (
    StreamSpec("span_seconds", kind="histogram", unit="s",
               doc="fenced host-side phase span durations, labeled by phase"),
    StreamSpec("link_bytes", kind="counter", unit="B",
               doc="cumulative analytic wire bytes per gossip buffer/channel "
                   "(label = buffer/channel-tag), all nodes"),
    StreamSpec("kernel_launches", kind="counter",
               doc="fused-op kernel launches per op (trace-time count from "
                   "repro.kernels.api)"),
)


class Telemetry:
    """The unified telemetry hub.

    config:  optional run configuration (any JSON-able object) hashed into
             the run metadata's ``config_hash``.
    spans:   enable host-side phase-span timing.  With spans on, engines
             that support it (the Simulator) drive rounds phase-by-phase
             with ``block_until_ready`` fencing so per-phase durations are
             real; with spans off they keep their fully-scanned executors
             and the hub only collects streams/counters.
    meta:    override the auto-derived run metadata dict.
    """

    def __init__(self, config: Any = None, *, spans: bool = True,
                 meta: Optional[Dict[str, Any]] = None):
        from .export import run_metadata  # lazy: export imports nothing of ours

        self.meta: Dict[str, Any] = dict(meta) if meta is not None else run_metadata(config)
        self.spans = bool(spans)
        self._specs: Dict[str, StreamSpec] = {}
        # (name, label) -> list of (step, value); counters store increments
        self._series: Dict[Tuple[str, str], List[Tuple[Optional[int], Any]]] = {}
        self._totals: Dict[Tuple[str, str], float] = {}
        self._events: List[Dict[str, Any]] = []
        self._kernel_seen: Dict[str, int] = {}
        for spec in _BUILTIN_STREAMS:
            self.register_stream(spec)

    # -- registry ----------------------------------------------------------
    def register_stream(self, spec_or_name, **kw) -> StreamSpec:
        """Register a stream (idempotent for an identical spec; conflicting
        re-registration is an error — a silently retyped stream would
        corrupt every exporter reading it)."""
        spec = (
            spec_or_name
            if isinstance(spec_or_name, StreamSpec)
            else StreamSpec(spec_or_name, **kw)
        )
        prev = self._specs.get(spec.name)
        if prev is not None and prev != spec:
            raise ValueError(
                f"stream {spec.name!r} already registered as {prev}, "
                f"conflicting re-registration: {spec}"
            )
        self._specs[spec.name] = spec
        return spec

    def spec(self, name: str) -> StreamSpec:
        try:
            return self._specs[name]
        except KeyError:
            raise KeyError(
                f"unknown stream {name!r}; registered: {sorted(self._specs)}"
            ) from None

    @property
    def streams(self) -> Tuple[str, ...]:
        return tuple(sorted(self._specs))

    # -- recording ---------------------------------------------------------
    @staticmethod
    def _value(v):
        arr = np.asarray(v)
        return float(arr) if arr.ndim == 0 else arr.astype(np.float64)

    def record(self, name: str, value, *, step: Optional[int] = None,
               label: str = "") -> None:
        """Record one sample into a REGISTERED stream.  Gauges/histograms
        store the value; counters treat ``value`` as an increment."""
        spec = self.spec(name)
        v = self._value(value)
        key = (name, label)
        self._series.setdefault(key, []).append((step, v))
        if spec.kind == "counter":
            self._totals[key] = self._totals.get(key, 0.0) + float(np.sum(v))

    def gauge(self, name: str, value, *, step: Optional[int] = None,
              label: str = "") -> None:
        """Convenience: record into ``name``, auto-registering it as a
        scalar gauge when unknown (ad-hoc eval metrics)."""
        if name not in self._specs:
            self.register_stream(StreamSpec(name, kind="gauge"))
        self.record(name, value, step=step, label=label)

    def record_many(self, values: Dict[str, Any], *, step: Optional[int] = None,
                    label: str = "") -> None:
        for k, v in values.items():
            self.record(k, v, step=step, label=label)

    def record_event(self, event: Dict[str, Any]) -> None:
        """Append a raw exporter event (span records use this so the JSONL
        stream carries per-round phase durations as first-class events)."""
        self._events.append(dict(event))

    # -- cross-cutting recorders ------------------------------------------
    def record_link_bytes(self, per_round: Dict[str, float], *,
                          rounds: int = 1, factor: float = 1.0,
                          step: Optional[int] = None) -> None:
        """Accumulate per-buffer/channel link-byte counters: ``per_round``
        maps a ``buffer/channel-tag`` label to analytic bytes ONE round puts
        on the wire (all nodes; see ``repro.compression.channels.
        link_bytes_per_round``).  ``factor`` scales event-triggered channels
        by their measured send fraction."""
        for label, per in per_round.items():
            self.record("link_bytes", float(per) * int(rounds) * float(factor),
                        step=step, label=label)

    def record_kernel_launches(self, *, step: Optional[int] = None) -> Dict[str, int]:
        """Fold the fused-op backend's trace-time launch counters into the
        ``kernel_launches`` counter stream (one label per op), recording only
        the delta since the last call.  Returns the delta."""
        from ..kernels import api  # lazy: keep the hub importable standalone

        counts = api.launch_counts()
        delta = {
            op: n - self._kernel_seen.get(op, 0)
            for op, n in counts.items()
            if n - self._kernel_seen.get(op, 0)
        }
        for op, n in delta.items():
            self.record("kernel_launches", n, step=step, label=op)
        self._kernel_seen = dict(counts)
        return delta

    # -- views -------------------------------------------------------------
    def labels(self, name: str) -> Tuple[str, ...]:
        self.spec(name)
        return tuple(sorted({lb for (n, lb) in self._series if n == name}))

    def series(self, name: str, label: str = "") -> Tuple[np.ndarray, np.ndarray]:
        """(steps, values) of one stream/label; counters give increments."""
        self.spec(name)
        rows = self._series.get((name, label), [])
        steps = np.asarray([-1 if s is None else s for s, _ in rows], np.int64)
        vals = [v for _, v in rows]
        if vals and isinstance(vals[0], np.ndarray):
            return steps, np.stack(vals)
        return steps, np.asarray(vals, np.float64)

    def total(self, name: str, label: str = "") -> float:
        if self.spec(name).kind != "counter":
            raise ValueError(f"stream {name!r} is not a counter")
        return self._totals.get((name, label), 0.0)

    @staticmethod
    def _summarize(values: np.ndarray) -> Dict[str, float]:
        flat = np.asarray(values, np.float64).ravel()
        if flat.size == 0:
            return {"count": 0}
        return {
            "count": int(flat.size),
            "sum": float(flat.sum()),
            "mean": float(flat.mean()),
            "p50": float(np.percentile(flat, 50)),
            "p95": float(np.percentile(flat, 95)),
            "max": float(flat.max()),
        }

    def collect(self) -> Dict[str, Dict[str, Any]]:
        """One structured snapshot of every registered stream: the spec, the
        per-label series, counter totals and histogram summaries."""
        out: Dict[str, Dict[str, Any]] = {}
        for name, spec in sorted(self._specs.items()):
            entry: Dict[str, Any] = {
                "spec": dataclasses.asdict(spec),
                "series": {},
            }
            for label in self.labels(name):
                steps, vals = self.series(name, label)
                series = {"steps": steps.tolist(), "values": vals.tolist()}
                if spec.kind == "counter":
                    series["total"] = self.total(name, label)
                if spec.kind == "histogram":
                    series["summary"] = self._summarize(vals)
                entry["series"][label] = series
            out[name] = entry
        return out

    @property
    def events(self) -> List[Dict[str, Any]]:
        return list(self._events)

    # -- exporters (see repro.telemetry.export) ----------------------------
    def export_jsonl(self, path: str) -> int:
        from .export import write_jsonl

        return write_jsonl(self, path)

    def prometheus(self, prefix: str = "repro") -> str:
        from .export import prometheus_text

        return prometheus_text(self, prefix=prefix)


def _register_fields(hub: Telemetry, fields: Sequence[str], doc: str) -> None:
    for f in fields:
        hub.register_stream(StreamSpec(f, kind="gauge", doc=doc))


def register_training_streams(hub: Telemetry) -> None:
    """Register the scenario engine's per-round stream fields as gauges."""
    _register_fields(hub, TRAINING_STREAM_FIELDS,
                     "per-round on-device training stream "
                     "(repro.scenarios.metrics)")


def register_runtime_streams(hub: Telemetry) -> None:
    """Register the elastic runtime's membership/liveness/resync streams."""
    doc = "elastic-runtime membership/liveness stream (repro.runtime)"
    hub.register_stream(StreamSpec("membership_epoch", kind="gauge", doc=doc))
    hub.register_stream(StreamSpec("active_workers", kind="gauge", doc=doc))
    hub.register_stream(StreamSpec("heartbeat_age", kind="gauge", unit="s",
                                   doc=doc + "; label = worker"))
    hub.register_stream(StreamSpec("round_seconds", kind="histogram", unit="s",
                                   doc="wall time of one elastic round "
                                       "(issue -> all DONEs)"))
    hub.register_stream(StreamSpec("contrib_seconds", kind="histogram", unit="s",
                                   doc="worker-side ROUND -> CONTRIB wall time "
                                       "(includes injected straggler sleep)"))
    hub.register_stream(StreamSpec("resync_seconds", kind="histogram", unit="s",
                                   doc="rejoin resync latency (checkpoint "
                                       "bundle -> RESYNC_OK)"))
    hub.register_stream(StreamSpec("socket_round_bytes", kind="histogram",
                                   unit="B",
                                   doc="measured control-channel bytes (tx+rx, "
                                       "framed) that crossed the coordinator's "
                                       "sockets during one round"))
