"""Online convergence diagnostics for DSE-MVR runs.

The paper's claims are *rate* claims: consensus error ``||X - X̄||²`` and
tracking error ``Σᵢ ||bᵢ - ḡ||²`` decay at rates governed by the spectral
gap, the heterogeneity level and the gradient noise (see also DGT with
local steps, arXiv 2301.01313, and arXiv 2403.15654, which use the same
quantities as the diagnostic axis).  The engines already compute these
on-device per round (``repro.scenarios.metrics``); this module watches the
resulting *streams* online and turns them into judgements:

  * :class:`OnlineStat` — EWMA level + trend per series, windowed log-slope
    for decay-rate estimation, peak tracking;
  * :class:`DiagnosticsMonitor` — feed it per-round observations
    (``observe(step, consensus=..., tracking_err=..., loss=...)`` or a whole
    engine streams dict via ``observe_streams``); it maintains the online
    stats, emits **anomaly events** into the telemetry hub the moment a
    threshold/trend rule fires (stall, divergence, consensus blow-up after
    a membership fault), and renders a :meth:`diagnose` report.

Anomaly rules (all with hysteresis — one event per episode, re-armed when
the condition clears):

``stall``              loss EWMA trend ≈ 0 and stationarity proxy not
                       decaying over the trailing window.
``divergence``         loss (or gradient norm) EWMA grows for
                       ``patience`` consecutive observations, or a
                       non-finite value shows up anywhere.
``consensus_blowup``   consensus error jumps > ``blowup_factor`` × its
                       pre-fault EWMA within ``fault_window`` rounds of a
                       membership-epoch bump (the signature of a resync or
                       ``W_t`` renormalization gone wrong).

Everything is plain host-side float math over scalars that already left the
device — the monitor adds no device syncs and is safe to run per round.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

__all__ = ["OnlineStat", "DiagnosticsMonitor"]


def _finite(x: Optional[float]) -> bool:
    return x is not None and math.isfinite(x)


class OnlineStat:
    """EWMA level/trend + windowed log-slope for one scalar series."""

    def __init__(self, alpha: float = 0.3, window: int = 8):
        self.alpha = float(alpha)
        self.window = int(window)
        self.n = 0
        self.last: Optional[float] = None
        self.ewma: Optional[float] = None
        self.trend = 0.0  # EWMA of successive differences
        self.peak: Optional[float] = None
        self._tail: List[float] = []  # trailing raw values for log-slope

    def update(self, value: float) -> None:
        value = float(value)
        if self.ewma is None:
            self.ewma = value
        else:
            self.trend = (1 - self.alpha) * self.trend + self.alpha * (value - self.last)
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * value
        self.last = value
        self.peak = value if self.peak is None else max(self.peak, value)
        self._tail.append(value)
        if len(self._tail) > self.window:
            self._tail.pop(0)
        self.n += 1

    def log_slope(self) -> Optional[float]:
        """Least-squares slope of log(value) over the trailing window —
        the per-round decay exponent (negative = decaying, the healthy
        sign for consensus/tracking/stationarity series)."""
        ys = [math.log(v) for v in self._tail if v > 0.0]
        k = len(ys)
        if k < 3:
            return None
        xs = range(k)
        mx = (k - 1) / 2.0
        my = sum(ys) / k
        num = sum((x - mx) * (y - my) for x, y in zip(xs, ys))
        den = sum((x - mx) ** 2 for x in xs)
        return num / den if den else None

    def summary(self) -> Dict[str, Any]:
        return {
            "n": self.n,
            "last": self.last,
            "ewma": self.ewma,
            "trend": self.trend,
            "peak": self.peak,
            "log_slope": self.log_slope(),
        }


#: engine stream name -> monitor series name (identity unless renamed)
_STREAM_SERIES = {
    "consensus": "consensus",
    "tracking_err": "tracking_err",
    "loss": "loss",
    "grad_norm": "grad_norm",
    "replica_drift": "replica_drift",
}


class DiagnosticsMonitor:
    """Watches convergence series online; records anomalies as telemetry
    events; renders a ``diagnose()`` report.

    ``hub`` is an optional :class:`repro.telemetry.Telemetry`; when given,
    each anomaly becomes a first-class event
    ``{"event": "anomaly", "kind": ..., "step": ..., "detail": ...}`` and a
    monotone ``anomalies`` counter sample, so anomalies ship over the same
    drain/export paths as everything else (JSONL, Prometheus, /trace).
    """

    def __init__(self, hub=None, *, alpha: float = 0.3, window: int = 8,
                 patience: int = 4, stall_tol: float = 1e-3,
                 blowup_factor: float = 10.0, fault_window: int = 3):
        self.hub = hub
        self.alpha = float(alpha)
        self.window = int(window)
        self.patience = int(patience)
        self.stall_tol = float(stall_tol)
        self.blowup_factor = float(blowup_factor)
        self.fault_window = int(fault_window)

        self.stats: Dict[str, OnlineStat] = {}
        self.anomalies: List[Dict[str, Any]] = []
        self.steps = 0
        self._grow_streak = 0
        self._stall_streak = 0
        self._active: Dict[str, bool] = {}  # hysteresis latches per kind
        # membership-fault context for the blow-up rule
        self._last_epoch: Optional[int] = None
        self._fault_step: Optional[int] = None
        self._prefault_consensus: Optional[float] = None
        if hub is not None:
            hub.register_stream("anomalies", kind="counter", axis="scalar")

    # ------------------------------------------------------------- intake
    def _stat(self, name: str) -> OnlineStat:
        if name not in self.stats:
            self.stats[name] = OnlineStat(self.alpha, self.window)
        return self.stats[name]

    def observe(self, step: int, *, epoch: Optional[int] = None,
                **series: Optional[float]) -> List[Dict[str, Any]]:
        """Feed one round's scalars; returns anomalies fired this step."""
        fired: List[Dict[str, Any]] = []
        self.steps += 1

        if epoch is not None:
            if self._last_epoch is not None and epoch != self._last_epoch:
                st = self.stats.get("consensus")
                self._fault_step = step
                self._prefault_consensus = st.ewma if st else None
            self._last_epoch = int(epoch)

        for name, value in series.items():
            if value is None:
                continue
            value = float(value)
            if not math.isfinite(value):
                fired += self._fire("divergence", step,
                                    f"non-finite {name} at round {step}")
                continue
            self._stat(name).update(value)

        fired += self._check_divergence(step)
        fired += self._check_stall(step)
        fired += self._check_consensus_blowup(step)
        return fired

    def observe_streams(self, streams: Dict[str, Any],
                        epochs: Optional[List[int]] = None) -> None:
        """Replay a whole engine ``out["streams"]`` dict (arrays indexed by
        round) through :meth:`observe` — the offline entry point used by the
        single-process engines and by tests."""
        series = {
            out_name: list(map(float, streams[in_name]))
            for in_name, out_name in _STREAM_SERIES.items()
            if in_name in streams
        }
        if not series:
            return
        n = min(len(v) for v in series.values())
        for t in range(n):
            epoch = int(epochs[t]) if epochs is not None and t < len(epochs) else None
            self.observe(t, epoch=epoch,
                         **{k: v[t] for k, v in series.items()})

    # ------------------------------------------------------------- rules
    def _fire(self, kind: str, step: int, detail: str) -> List[Dict[str, Any]]:
        if self._active.get(kind):
            return []
        self._active[kind] = True
        anomaly = {"kind": kind, "step": int(step), "detail": detail}
        self.anomalies.append(anomaly)
        if self.hub is not None:
            self.hub.record_event({"event": "anomaly", **anomaly})
            self.hub.record("anomalies", 1.0, step=step, label=kind)
        return [anomaly]

    def _clear(self, kind: str) -> None:
        self._active[kind] = False

    def _check_divergence(self, step: int) -> List[Dict[str, Any]]:
        st = self.stats.get("loss") or self.stats.get("grad_norm")
        if st is None or st.n < 2 or not _finite(st.trend):
            return []
        scale = abs(st.ewma) if _finite(st.ewma) and st.ewma else 1.0
        if st.trend > self.stall_tol * scale:
            self._grow_streak += 1
        else:
            self._grow_streak = 0
            self._clear("divergence")
        if self._grow_streak >= self.patience:
            return self._fire(
                "divergence", step,
                f"loss EWMA rising for {self._grow_streak} rounds "
                f"(trend={st.trend:.3g}, ewma={st.ewma:.3g})")
        return []

    def _check_stall(self, step: int) -> List[Dict[str, Any]]:
        loss = self.stats.get("loss")
        if loss is None or loss.n < self.window:
            return []
        scale = abs(loss.ewma) if _finite(loss.ewma) and loss.ewma else 1.0
        flat = abs(loss.trend) <= self.stall_tol * scale
        # stationarity proxy: gradient norm (or tracking error) should still
        # be decaying if flat loss means "converged" rather than "stuck"
        grad = self.stats.get("grad_norm") or self.stats.get("tracking_err")
        decaying = False
        if grad is not None:
            slope = grad.log_slope()
            decaying = slope is not None and slope < -self.stall_tol
        if flat and grad is not None and not decaying:
            self._stall_streak += 1
        else:
            self._stall_streak = 0
            self._clear("stall")
        if self._stall_streak >= self.patience:
            return self._fire(
                "stall", step,
                f"loss flat (trend={loss.trend:.3g}) with no stationarity "
                f"decay over the last {self.window} rounds")
        return []

    def _check_consensus_blowup(self, step: int) -> List[Dict[str, Any]]:
        if self._fault_step is None:
            return []
        if step - self._fault_step > self.fault_window:
            self._fault_step = None
            self._clear("consensus_blowup")
            return []
        st = self.stats.get("consensus")
        base = self._prefault_consensus
        if st is None or not _finite(st.last) or not _finite(base) or base <= 0:
            return []
        if st.last > self.blowup_factor * base:
            return self._fire(
                "consensus_blowup", step,
                f"consensus error {st.last:.3g} is "
                f"{st.last / base:.1f}x the pre-fault EWMA {base:.3g} "
                f"within {step - self._fault_step} rounds of the epoch bump")
        return []

    # ------------------------------------------------------------- report
    def diagnose(self) -> Dict[str, Any]:
        """One-shot report: per-series online stats, the derived
        effective-heterogeneity proxy and stationarity decay, all anomalies,
        and a coarse verdict (``healthy`` / ``suspect`` / ``unhealthy``)."""
        series = {name: st.summary() for name, st in self.stats.items()}
        tracking = self.stats.get("tracking_err")
        consensus = self.stats.get("consensus")
        grad = self.stats.get("grad_norm") or tracking
        report: Dict[str, Any] = {
            "steps": self.steps,
            "series": series,
            # across-node tracker variance is exactly the quantity the
            # paper's rates charge to heterogeneity once noise is averaged
            "effective_heterogeneity": tracking.ewma if tracking else None,
            "stationarity_decay": grad.log_slope() if grad else None,
            "consensus_decay": consensus.log_slope() if consensus else None,
            "anomalies": list(self.anomalies),
        }
        kinds = {a["kind"] for a in self.anomalies}
        if {"divergence", "consensus_blowup"} & kinds:
            report["verdict"] = "unhealthy"
        elif kinds:
            report["verdict"] = "suspect"
        else:
            report["verdict"] = "healthy"
        return report
