"""Cross-process causal tracing: one timeline for the whole fleet.

PR 7's phase spans and PR 8's multi-process runtime each observe their own
process: every worker fences local/gossip/resync spans into its own hub, the
coordinator times rounds and resyncs in its hub, and the merged JSONL stream
interleaves them without any causal glue.  This module adds the glue:

  * the coordinator mints a **per-round trace id** (``round_trace_id``) and
    carries it on every round-scoped control-channel message (see
    ``repro.runtime.protocol.attach_trace``);
  * every process records its spans through a :class:`TraceRecorder`, which
    stamps each span event with a **wall-clock anchor** (``t0``), duration
    and the trace id it was working under — these events ride the existing
    run-stamped record stream (``RecordCursor`` over the control channel for
    workers, the coordinator's own hub locally), so stitching needs no new
    transport;
  * :func:`trace_events` stitches any collection of stamped records into
    Chrome trace-event JSON (the format Perfetto / ``chrome://tracing`` load
    directly): one track per process (pid from the run stamp, named by its
    ``process`` role), ``X`` duration events for spans, ``i`` instants for
    membership transitions, the shared trace id + round + epoch in ``args``.

A 4-process kill+rejoin run therefore renders as ONE timeline: the abandoned
round attempt on the coordinator track (``abandoned: true`` in its args),
the epoch-bump instant, the rejoining worker's ``resync`` span and the
re-issued round's spans on every surviving worker — all joined by the same
per-round trace id.

Wall-clock anchors (``time.time()``) are comparable across processes on one
host, which is the elastic runtime's deployment unit; cross-host skew would
shift tracks relative to each other but never corrupt intra-process timing
or the trace-id causality.
"""
from __future__ import annotations

import contextlib
import json
import os
import time
import uuid
from typing import Any, Dict, Iterable, Iterator, List, Optional

__all__ = [
    "new_run_id",
    "round_trace_id",
    "TraceRecorder",
    "trace_events",
    "trace_index",
    "write_chrome_trace",
]

#: event kinds a hub record must carry to be stitchable (plus a ``t0`` anchor)
_SPAN_EVENTS = ("span", "instant")


def new_run_id() -> str:
    """A short random run id — the prefix every round trace id shares."""
    return uuid.uuid4().hex[:8]


def round_trace_id(run_id: str, round_: int) -> str:
    """The ONE trace id for round ``round_``: every attempt of the round
    (including abandoned ones after a mid-round death), the resyncs that
    re-admit workers into it and every worker's phase spans all carry it."""
    return f"{run_id}/r{int(round_):05d}"


class TraceRecorder:
    """Wall-clock-anchored span/instant recorder over a telemetry hub.

    Unlike :func:`repro.telemetry.spans.span` (host timers for the
    single-process engines, active only when ``hub.spans``), the recorder is
    explicit — the runtime opts in per call site — and every event carries
    the ``t0`` anchor + trace id the cross-process stitcher needs.  Span
    durations are additionally folded into the hub's ``span_seconds``
    histogram so ``/metrics`` exposes per-phase timing without reading the
    event stream.  With ``hub`` None every method is a no-op.
    """

    def __init__(self, hub):
        self.hub = hub

    @contextlib.contextmanager
    def span(self, phase: str, *, trace: Optional[str] = None,
             step: Optional[int] = None, epoch: Optional[int] = None,
             ) -> Iterator[Dict[str, Any]]:
        """Time one phase; yields a dict the caller may add extra args to
        (e.g. ``info["abandoned"] = True``) before the span closes."""
        info: Dict[str, Any] = {}
        if self.hub is None:
            yield info
            return
        t0 = time.time()
        p0 = time.perf_counter()
        try:
            yield info
        finally:
            dt = time.perf_counter() - p0
            ev: Dict[str, Any] = {
                "event": "span", "phase": phase, "step": step,
                "seconds": dt, "t0": t0,
            }
            if trace is not None:
                ev["trace"] = trace
            if epoch is not None:
                ev["epoch"] = epoch
            ev.update(info)
            self.hub.record_event(ev)
            self.hub.record("span_seconds", dt, step=step, label=phase)

    def instant(self, name: str, *, trace: Optional[str] = None,
                step: Optional[int] = None, **args: Any) -> None:
        """A zero-duration marker (epoch bump, kill observed, ...)."""
        if self.hub is None:
            return
        ev: Dict[str, Any] = {
            "event": "instant", "phase": name, "step": step, "t0": time.time(),
        }
        if trace is not None:
            ev["trace"] = trace
        ev.update(args)
        self.hub.record_event(ev)


# --------------------------------------------------------------- stitching
_ARG_KEYS = ("trace", "epoch", "abandoned", "worker", "reason", "to_epoch")


def _pid_of(rec: Dict[str, Any]) -> int:
    run = rec.get("run") or {}
    try:
        return int(run.get("pid", 0))
    except (TypeError, ValueError):
        return 0


def trace_events(records: Iterable[Dict[str, Any]],
                 base_ts: Optional[float] = None) -> List[Dict[str, Any]]:
    """Stitch stamped span/instant records into Chrome trace events.

    ``records`` are JSONL-shaped hub records (each with its origin's ``run``
    stamp) from ANY number of processes; records without a ``t0`` wall-clock
    anchor (e.g. the single-process engines' plain spans) are skipped.
    Returns ``process_name`` metadata events followed by the span/instant
    events sorted by timestamp within each (pid, tid) track — the Chrome
    trace-event contract Perfetto expects.
    """
    spans = [
        r for r in records
        if r.get("event") in _SPAN_EVENTS and r.get("t0") is not None
    ]
    if not spans:
        return []
    if base_ts is None:
        base_ts = min(float(r["t0"]) for r in spans)

    procs: Dict[int, str] = {}
    out: List[Dict[str, Any]] = []
    for r in spans:
        pid = _pid_of(r)
        run = r.get("run") or {}
        procs.setdefault(pid, str(run.get("process", f"pid:{pid}")))
        args = {k: r[k] for k in _ARG_KEYS if r.get(k) is not None}
        if r.get("step") is not None:
            args["round"] = int(r["step"])
        ev: Dict[str, Any] = {
            "name": str(r.get("phase", "?")),
            "cat": "repro",
            "ts": round((float(r["t0"]) - base_ts) * 1e6, 1),
            "pid": pid,
            "tid": 1,
            "args": args,
        }
        if r["event"] == "span":
            ev["ph"] = "X"
            ev["dur"] = round(float(r.get("seconds", 0.0)) * 1e6, 1)
        else:
            ev["ph"] = "i"
            ev["s"] = "p"
        out.append(ev)
    out.sort(key=lambda e: (e["pid"], e["tid"], e["ts"]))
    meta = [
        {"name": "process_name", "ph": "M", "pid": pid, "tid": 1,
         "args": {"name": name}}
        for pid, name in sorted(procs.items())
    ]
    return meta + out


def trace_index(events: Iterable[Dict[str, Any]]) -> Dict[str, Dict[str, Any]]:
    """Summarize stitched events per trace id: which pids and phases carried
    it, which round it belongs to, whether an attempt was abandoned.  The CI
    smoke and the acceptance tests assert on this view."""
    idx: Dict[str, Dict[str, Any]] = {}
    for ev in events:
        trace = (ev.get("args") or {}).get("trace")
        if trace is None:
            continue
        entry = idx.setdefault(trace, {
            "pids": set(), "phases": set(), "rounds": set(), "abandoned": False,
        })
        entry["pids"].add(ev["pid"])
        entry["phases"].add(ev["name"])
        if "round" in ev["args"]:
            entry["rounds"].add(int(ev["args"]["round"]))
        if ev["args"].get("abandoned"):
            entry["abandoned"] = True
    for entry in idx.values():
        entry["pids"] = sorted(entry["pids"])
        entry["phases"] = sorted(entry["phases"])
        entry["rounds"] = sorted(entry["rounds"])
    return idx


def write_chrome_trace(path: str, records: Iterable[Dict[str, Any]]) -> int:
    """Stitch ``records`` and write a Perfetto-loadable trace file; returns
    the number of trace events written (0 leaves an empty-but-valid file)."""
    events = trace_events(records)
    dirname = os.path.dirname(os.path.abspath(path))
    os.makedirs(dirname, exist_ok=True)
    with open(path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
    return len(events)
